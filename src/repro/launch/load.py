"""Serve-under-load launcher: seeded traffic -> slot-pool server ->
SLO report (docs/serving.md).

Drives one server of ``repro.launch.serve`` (LM or streaming ASR,
picked by the arch family) through a deterministic
:class:`repro.serving.Workload` trace with the priority-tiered
admission controller, and prints the per-run SLO summary in the shared
``name,value,derived`` CSV schema of ``launch/evaluate.py`` and
``benchmarks/run.py``.  Virtual time by default — the whole overload
scenario runs in milliseconds of model compute plus a deterministic
clock, so the same seed reproduces every row; ``--wall`` switches to
wall-clock timestamps for real measurements.

PYTHONPATH=src python -m repro.launch.load --arch smollm-360m --reduced \
    --qps 2 --horizon 10 --slots 2 --max-len 32
PYTHONPATH=src python -m repro.launch.load --arch swb2000-blstm --reduced \
    --qps 1 --horizon 10 --slots 2 --chunk-frames 8 --beam-width 3
"""
from __future__ import annotations

import argparse
import sys
import time

from repro import obs
from repro.configs import get_arch
from repro.serving import (CostModel, ServingLoop, VirtualClock, WallClock,
                           Workload, generate_trace, make_payload,
                           print_csv_rows, prompt_capacity, summary_rows)
from repro.serving.admission import OK


def instrument_server(server):
    """Wrap ``submit``/``step_wave`` with wall-time measurement: each
    admission's and wave's real service time lands in ``wall``
    histograms and in the returned observation lists, which
    :func:`repro.obs.fit_cost_model` turns into calibrated
    ``CostModel`` parameters (the ROADMAP "calibrate CostModel from
    --wall runs" loop)."""
    admit_obs, wave_obs = [], []
    orig_submit, orig_wave = server.submit, server.step_wave

    def submit(req, payload):
        t0 = time.perf_counter()
        res = orig_submit(req, payload)
        dt = time.perf_counter() - t0
        if res.reason == OK:
            admit_obs.append(dt)
            obs.histogram("load/admit_s", wall=True).observe(dt)
        return res

    def step_wave():
        t0 = time.perf_counter()
        out = orig_wave()
        dt = time.perf_counter() - t0
        wave_obs.append((out[2], dt))       # (work, measured seconds)
        obs.histogram("load/wave_s", wall=True).observe(dt)
        return out

    server.submit, server.step_wave = submit, step_wave
    return admit_obs, wave_obs


def calibration_rows(fit: dict):
    """CostModel calibration as shared-schema CSV rows — the values
    paste straight back into ``--admit-ms`` / ``--wave-ms`` /
    ``--work-us`` for a calibrated virtual-time run."""
    return [
        ("calib/admit_ms", fit["admit_s"] * 1e3,
         "measured mean admission service time (feed to --admit-ms)"),
        ("calib/wave_ms", fit["wave_base_s"] * 1e3,
         "fit intercept: base cost per wave (feed to --wave-ms)"),
        ("calib/work_us", fit["per_work_s"] * 1e6,
         "fit slope: per token/frame (feed to --work-us)"),
        ("calib/n_waves", fit["n_waves"], "measured decode waves"),
        ("calib/resid_ms", fit["resid_s"] * 1e3,
         "rms residual of the wave-time fit"),
    ]


def build_server(cfg, args):
    """The slot-pool server for this arch family plus its payload mode."""
    from repro.launch.serve import AsrServer, PagedServer, Server
    from repro.serving.kvpool import cdiv

    if cfg.family == "lstm":
        server = AsrServer(
            cfg, slots=args.slots, max_frames=args.max_len,
            chunk=args.chunk_frames, beam=args.beam_width,
            kernel_impl=args.kernel_impl,
            topc=None if args.beam_topc < 0 else args.beam_topc)
        return server, "asr"
    if (args.cache or cfg.cache_mode) == "paged":
        page = args.page_size or cfg.page_size
        pool_pages = args.pool_pages or args.slots * cdiv(args.max_len,
                                                          page)
        server = PagedServer(cfg, pool_pages=pool_pages, page_size=page,
                             max_len=args.max_len,
                             kernel_impl=args.kernel_impl)
        return server, "lm"
    server = Server(cfg, slots=args.slots, max_len=args.max_len,
                    kernel_impl=args.kernel_impl)
    return server, "lm"


def build_workload(args, mode: str) -> Workload:
    tier_probs = tuple(float(p) for p in args.tier_probs.split(","))
    # payload lengths capped so every offered request is admissible
    # (prompt_capacity: the LM/ASR off-by-one contract in one place)
    len_max = prompt_capacity(args.max_len, mode)
    return Workload(
        qps=args.qps, horizon=args.horizon, seed=args.seed,
        tier_probs=tier_probs, len_median=args.len_median,
        len_sigma=args.len_sigma, len_min=1, len_max=len_max,
        diurnal_amp=args.diurnal_amp, diurnal_period=args.diurnal_period,
        patience=args.patience, deadline=args.deadline,
        max_new=args.max_new)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--qps", type=float, default=2.0,
                    help="mean offered arrival rate (requests per "
                         "virtual second)")
    ap.add_argument("--horizon", type=float, default=10.0,
                    help="offered-traffic window in virtual seconds")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed: same seed => identical trace, "
                         "payloads and SLO rows")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=32,
                    help="cache capacity (LM) / max utterance frames "
                         "(ASR) per slot; payload lengths are capped to "
                         "fit")
    ap.add_argument("--max-new", type=int, default=8,
                    help="LM decode budget per request")
    ap.add_argument("--chunk-frames", type=int, default=8,
                    help="ASR frames decoded per wave")
    ap.add_argument("--beam-width", type=int, default=0,
                    help="ASR beam width (0 = cfg beam_width)")
    ap.add_argument("--beam-topc", type=int, default=-1,
                    help="ASR per-frame top-C vocab pruning "
                         "(0 off, -1 cfg)")
    ap.add_argument("--kernel-impl", default="jax",
                    choices=["jax", "pallas"])
    ap.add_argument("--cache", default="",
                    choices=["", "dense", "paged"],
                    help="LM KV-cache layout: dense slot rows or the "
                         "paged page-pool server (default: "
                         "cfg.cache_mode)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="positions per KV page under --cache paged "
                         "(0 = cfg.page_size)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="physical pages in the paged pool (0 = slots * "
                         "max_len / page_size, the dense-equivalent HBM)")
    ap.add_argument("--tier-probs", default="0.25,0.75",
                    help="comma list of priority-tier draw probabilities "
                         "(tier 0 = highest; preempts lower tiers)")
    ap.add_argument("--diurnal-amp", type=float, default=0.0,
                    help="diurnal rate modulation amplitude in [0, 1)")
    ap.add_argument("--diurnal-period", type=float, default=60.0,
                    help="virtual seconds per diurnal cycle")
    ap.add_argument("--len-median", type=float, default=12.0,
                    help="lognormal median payload length")
    ap.add_argument("--len-sigma", type=float, default=0.5,
                    help="lognormal log-std of payload length")
    ap.add_argument("--patience", type=float, default=30.0,
                    help="queue wait after which an unstarted request "
                         "abandons (virtual s)")
    ap.add_argument("--deadline", type=float, default=60.0,
                    help="final-result SLO bound for the deadline-miss "
                         "row (virtual s)")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable slot preemption (tiers still order "
                         "the queue)")
    ap.add_argument("--wall", action="store_true",
                    help="wall-clock timestamps instead of the virtual "
                         "cost model (real measurements, not seeded-"
                         "reproducible)")
    ap.add_argument("--admit-ms", type=float, default=20.0,
                    help="virtual admission (prefill/forward) service "
                         "time, ms")
    ap.add_argument("--wave-ms", type=float, default=10.0,
                    help="virtual base cost per decode wave, ms")
    ap.add_argument("--work-us", type=float, default=0.0,
                    help="virtual cost per token decoded / frame "
                         "consumed, us")
    ap.add_argument("--min-done-per-tier", type=int, default=0,
                    help="exit nonzero unless every tier completes at "
                         "least this many requests (CI smoke gate)")
    ap.add_argument("--events", action="store_true",
                    help="print the structured per-request event stream "
                         "(offer/done with timestamps)")
    ap.add_argument("--trace-out", default="",
                    help="enable observability and write the run's "
                         "flight-recorder JSONL here (request events, "
                         "measured service times, calibration inputs; "
                         "docs/observability.md)")
    ap.add_argument("--trace-deterministic", action="store_true",
                    help="strip wall-clock fields from the JSONL so "
                         "two seeded runs emit byte-identical traces")
    ap.add_argument("--calibrate", action="store_true",
                    help="measure real submit/step_wave service times "
                         "and print calib/* rows: a least-squares "
                         "CostModel fit whose values feed back into "
                         "--admit-ms/--wave-ms/--work-us (implied by "
                         "--wall)")
    args = ap.parse_args(argv)

    if args.trace_out:
        obs.configure()
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    server, mode = build_server(cfg, args)
    calibrate = args.wall or args.calibrate
    admit_obs = wave_obs = None
    if calibrate:
        admit_obs, wave_obs = instrument_server(server)
    workload = build_workload(args, mode)
    trace = generate_trace(workload)
    print(f"[load] {mode} x {args.kernel_impl}: {len(trace)} offered "
          f"requests over {args.horizon:.3g}s at {args.qps:.3g} qps "
          f"({'wall' if args.wall else 'virtual'} time, "
          f"preempt={'off' if args.no_preempt else 'on'})", flush=True)

    payload_fn = lambda req: make_payload(
        req, mode=mode, vocab=cfg.vocab, input_dim=cfg.input_dim,
        seed=workload.seed)
    on_event = None
    if args.events:
        on_event = lambda kind, rid, now, kw: print(
            "[event] " + " ".join(
                [f"{kind} rid={rid} t={now:.6g}"]
                + [f"{k}={v}" for k, v in kw.items()]), flush=True)
    loop = ServingLoop(
        server, trace, payload_fn, n_tiers=len(workload.tier_probs),
        clock=WallClock() if args.wall else VirtualClock(),
        cost=CostModel(admit_s=args.admit_ms * 1e-3,
                       wave_base_s=args.wave_ms * 1e-3,
                       per_work_s=args.work_us * 1e-6),
        preempt=not args.no_preempt, on_event=on_event)
    loop.run()
    summary = loop.summary()

    derived = "wall s" if args.wall else "virtual s"
    rows = [("load/qps_offered", workload.qps, "requests per s"),
            ("load/waves", loop.n_waves, "decode waves"),
            ("load/elapsed_s", loop.clock.now(), derived)]
    rows += summary_rows(summary, "load", derived)
    if calibrate:
        rows += calibration_rows(obs.fit_cost_model(wave_obs, admit_obs))
    print_csv_rows(rows, header=True)
    if args.trace_out:
        n = obs.dump(args.trace_out,
                     deterministic=args.trace_deterministic)
        print(f"trace: {n} events -> {args.trace_out}")
        obs.reset()

    if args.min_done_per_tier > 0:
        short = {t: tv["done"] for t, tv in summary["per_tier"].items()
                 if tv["done"] < args.min_done_per_tier}
        if short:
            print(f"[load] FAIL: tiers below --min-done-per-tier="
                  f"{args.min_done_per_tier}: {short}", flush=True)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
