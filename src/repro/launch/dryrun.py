import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) pair this lowers + compiles the
real train/serve step against ShapeDtypeStruct stand-ins (no allocation)
on the production meshes:

* single pod  (16, 16)    = 256 chips, axes ('data', 'model')
* multi-pod   (2, 16, 16) = 512 chips, axes ('pod', 'data', 'model')

and records memory_analysis(), cost_analysis(), and the trip-count-correct
HLO analysis (FLOPs / bytes / per-collective bytes) into
``experiments/dryrun/<arch>__<shape>__<mesh>.json`` — the §Roofline tables
are generated from these artifacts by ``benchmarks/roofline.py``.

Train shapes lower the arch's own distributed strategy (the paper's
technique: learner replicas + ring mixing); multi-pod train uses the
paper's H-ring (sync within pod, AD-PSGD ring over the 'pod' axis).
Decode shapes lower ``serve_step`` (1 token against a seq_len KV cache).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b \
      --shape train_4k --multipod --save-hlo
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze_hlo
from repro.analysis.params import count_active_params, count_params
from repro.analysis.roofline import model_flops, roofline_terms
from repro.configs import ASSIGNED_ARCHS, get_arch, get_shape
from repro.core import strategies as ST
from repro.launch.mesh import make_production_mesh, rules_for, use_mesh
from repro.models import build_model
from repro.optim.optimizers import sgd
from repro.sharding import spec_tree_to_sds

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _sds_scalar(dtype=jnp.int32):
    return jax.ShapeDtypeStruct((), dtype)


def build_train_dryrun(cfg, mesh, rules, shape, *, multi_pod: bool):
    """(callable, args) for the strategy train step, all-SDS."""
    model = build_model(cfg)
    if multi_pod:
        strategy = ST.get_strategy("hring")
        n_learners = mesh.shape["pod"]
    else:
        strategy = ST.get_strategy(cfg.train_strategy)
        n_learners = cfg.n_learners if strategy.replicated else 1

    import functools
    loss_fn = functools.partial(
        model.loss_fn, batch_axis="" if strategy.replicated else "data")
    # the same substrate train.py would run (comm_* knobs), so the
    # HLO/collective picture matches the real step
    transport = ST.transport_from_cfg(cfg, strategy)
    step = ST.make_train_step(
        strategy, loss_fn, sgd(), lambda s: jnp.float32(0.1),
        n_learners=n_learners, microbatches=cfg.microbatches,
        pre_split=strategy.replicated, transport=transport)

    lead = ((n_learners, "learner"),) if strategy.replicated else ()
    params = spec_tree_to_sds(model.param_specs(), rules, extra_leading=lead)
    state = {"params": params, "opt": (), "step": _sds_scalar()}
    if strategy.stale:
        state["prev_params"] = params
    if strategy.replicated and transport.needs_state:
        # error-feedback trees as SDS (init_comm only reads leaf shapes)
        state["comm"] = jax.eval_shape(transport.init_comm, params)
    inputs = model.input_specs(shape, "train")
    if strategy.replicated:
        # pre-split the global batch: (B, ...) -> (L, B/L, ...) with the
        # learner dim explicitly sharded (data axis / pod axis for H-ring)
        from repro.sharding import ParamSpec

        def split(ps: ParamSpec):
            B = ps.shape[0]
            assert B % n_learners == 0, (B, n_learners)
            return ParamSpec((n_learners, B // n_learners) + ps.shape[1:],
                             ps.dtype, ("learner",) + ps.axes, ps.init,
                             ps.init_scale)

        inputs = jax.tree.map(split, inputs,
                              is_leaf=lambda x: isinstance(x, ParamSpec))
    batch = spec_tree_to_sds(inputs, rules)
    return step, (state, batch), {"strategy": strategy.name,
                                  "n_learners": n_learners}


def build_prefill_dryrun(cfg, mesh, rules, shape):
    model = build_model(cfg)
    long_ctx = shape.name == "long_500k"

    def step(params, batch):
        return model.prefill_fn(params, batch, cache_len=shape.seq_len,
                                long_context=long_ctx)

    params = spec_tree_to_sds(model.param_specs(), rules)
    batch = spec_tree_to_sds(model.input_specs(shape, "prefill"), rules)
    return step, (params, batch), {"strategy": "serve"}


def build_decode_dryrun(cfg, mesh, rules, shape):
    model = build_model(cfg)
    long_ctx = shape.name == "long_500k"

    def step(params, cache, tokens, pos):
        return model.decode_fn(params, cache, tokens, pos,
                               long_context=long_ctx)

    params = spec_tree_to_sds(model.param_specs(), rules)
    cache = spec_tree_to_sds(model.cache_specs(shape), rules)
    inp = spec_tree_to_sds(model.input_specs(shape, "decode"), rules)
    return step, (params, cache, inp["tokens"], inp["pos"]), \
        {"strategy": "serve"}


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            save_hlo: bool = False, out_dir: str = OUT_DIR,
            opt: bool = False, cfg_override=None) -> dict:
    cfg = cfg_override or get_arch(arch)
    if opt and cfg_override is None:
        cfg = cfg.optimized()
    shape = get_shape(shape_name)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    if opt:
        mesh_name += "_opt"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "variant": "optimized" if opt else "baseline",
           "status": "skipped"}

    if not cfg.supports_shape(shape_name):
        rec["reason"] = "skipped per DESIGN.md §Arch-applicability"
        return rec
    if shape.is_decode and not cfg.supports_decode:
        rec["reason"] = "no decode step for this family"
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, mesh, multi_pod=multi_pod)

    t0 = time.time()
    with use_mesh(mesh):
        if shape.kind == "train":
            fn, args, meta = build_train_dryrun(cfg, mesh, rules, shape,
                                                multi_pod=multi_pod)
        elif shape.kind == "prefill":
            fn, args, meta = build_prefill_dryrun(cfg, mesh, rules, shape)
        else:
            fn, args, meta = build_decode_dryrun(cfg, mesh, rules, shape)
        lowered = jax.jit(fn).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec.update(meta)
    rec["memory"] = {
        "argument_gb": ma.argument_size_in_bytes / 1e9,
        "output_gb": ma.output_size_in_bytes / 1e9,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "code_gb": ma.generated_code_size_in_bytes / 1e9,
    }
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    rec["cost_analysis"] = {"flops": ca.get("flops", 0.0),
                            "bytes": ca.get("bytes accessed", 0.0)}

    txt = compiled.as_text()
    st = analyze_hlo(txt)
    rec["hlo"] = st.to_json()

    chips = 512 if multi_pod else 256
    rec["chips"] = chips
    rec["roofline"] = roofline_terms(
        {"flops": st.flops, "bytes": st.bytes,
         "collective_bytes": st.collective_bytes}, chips=chips)

    model = build_model(cfg)
    specs = model.param_specs()
    n_total = count_params(specs)
    n_active = count_active_params(cfg, specs)
    rec["params_total"] = n_total
    rec["params_active_nonembed"] = n_active
    mf = model_flops(cfg, shape, n_active, shape.kind)
    rec["model_flops"] = mf
    hlo_global = st.flops * chips
    rec["model_flops_ratio"] = mf / hlo_global if hlo_global else 0.0
    rec["status"] = "ok"

    if save_hlo:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir, f"{arch}__{shape_name}__{mesh_name}.hlo.txt"),
                "w") as f:
            f.write(txt)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf optimized overlay "
                         "(ArchConfig.optimized())")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args(argv)

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else [args.arch]
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if args.shape == "all" else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multipod]

    os.makedirs(args.out_dir, exist_ok=True)
    failures = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                tag = (f"{arch}__{shape}__"
                       f"{'multipod_2x16x16' if multi_pod else 'pod_16x16'}"
                       f"{'_opt' if args.opt else ''}")
                try:
                    rec = run_one(arch, shape, multi_pod=multi_pod,
                                  save_hlo=args.save_hlo,
                                  out_dir=args.out_dir, opt=args.opt)
                except Exception as e:  # a failure here is a sharding bug
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multipod" if multi_pod else "pod",
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                with open(os.path.join(args.out_dir, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"{tag:70s} ok  lower {rec['lower_s']:6.1f}s "
                          f"compile {rec['compile_s']:6.1f}s "
                          f"dom={r['dominant']:10s} bound={r['bound_s']:.3e}s",
                          flush=True)
                else:
                    print(f"{tag:70s} {rec['status']}: "
                          f"{rec.get('reason', rec.get('error', ''))[:110]}",
                          flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run failures")
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
