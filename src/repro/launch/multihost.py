"""Multi-host / multi-process launch scaffolding.

On a real TPU pod each host runs this same program; ``initialize()`` wires
``jax.distributed`` (coordinator discovery via TPU metadata or explicit
flags), after which ``jax.devices()`` spans the full pod and
``make_production_mesh()`` lays the global mesh over it.  Data loading is
per-host: each host synthesizes/loads only the batch rows that live on its
addressable devices (``host_batch_slice``), and global arrays are built
with ``jax.make_array_from_process_local_data``.

In this CPU container there is a single process; everything degrades to
the local path (tested in tests/test_multihost.py), and the multi-process
behaviour is exercised on real clusters via the same entry points:

  python -m repro.launch.train --arch ... --mesh pod   # per host, with
  JAX_COORDINATOR_ADDRESS / JAX_NUM_PROCESSES / JAX_PROCESS_ID set.
"""
from __future__ import annotations

import os

import jax
import numpy as np


def initialize(coordinator: str = "", num_processes: int = 0,
               process_id: int = -1) -> bool:
    """Initialize jax.distributed when running multi-process; no-op (False)
    in single-process runs so tests/examples need no special casing."""
    coordinator = coordinator or os.environ.get("JAX_COORDINATOR_ADDRESS", "")
    num_processes = num_processes or int(
        os.environ.get("JAX_NUM_PROCESSES", "0"))
    if not coordinator or num_processes <= 1:
        return False
    process_id = process_id if process_id >= 0 else int(
        os.environ.get("JAX_PROCESS_ID", "0"))
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def host_batch_slice(global_batch: int):
    """(start, size) of this host's rows of the global batch, assuming the
    batch dim is sharded over data-parallel devices in process order (the
    layout make_production_mesh produces)."""
    n = jax.process_count()
    idx = jax.process_index()
    assert global_batch % n == 0, (global_batch, n)
    per = global_batch // n
    return idx * per, per


def make_global_batch(batch_np: dict, mesh, rules, input_axes: dict):
    """Host-local numpy rows -> global jax.Arrays on the mesh.

    batch_np holds ONLY this host's rows (see host_batch_slice).
    input_axes: leaf name -> logical axes tuple (as in Model.input_specs).
    Single-process: a plain device_put with the same shardings.
    """
    out = {}
    for k, v in batch_np.items():
        axes = input_axes[k]
        global_shape = (v.shape[0] * jax.process_count(),) + v.shape[1:]
        sharding = rules.sharding(global_shape, axes)
        if jax.process_count() == 1:
            out[k] = jax.device_put(np.asarray(v), sharding)
        else:
            out[k] = jax.make_array_from_process_local_data(
                sharding, np.asarray(v), global_shape)
    return out
