"""Render a flight-recorder JSONL trace into a human-readable report
(docs/observability.md §Reading a trace).

Reads the ``--trace-out`` JSONL of any launcher (train / serve / load /
evaluate), validates it against the shared event schema (exit 1 on a
violation — the CI obs smoke gates on this), and prints:

* the per-span-name time breakdown (count, total, *self* time with
  child spans attributed to their parents via ``id``/``parent``),
* the compile-vs-steady split of every profiled jit entry point,
* counter / gauge values (bytes on wire per strategy, kernel VMEM
  accounting) and histogram percentiles,
* request outcome counts and latency percentiles, rebuilt from the
  ``request/*`` instants via
  :func:`repro.serving.slo.fold_request_events`.

``--chrome OUT`` additionally converts the trace to Chrome
``trace_event`` JSON (open in chrome://tracing or ui.perfetto.dev);
``--csv`` emits the report in the shared ``name,value,derived`` schema
instead of the text tables.

PYTHONPATH=src python -m repro.launch.obsreport /tmp/train.jsonl
PYTHONPATH=src python -m repro.launch.obsreport /tmp/serve.jsonl \
    --chrome /tmp/serve_chrome.json --top 20
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from collections import defaultdict

from repro.obs import chrome_trace, print_csv_rows, read_jsonl, \
    validate_events
from repro.serving.slo import fold_request_events, summarize

_PHASES = ("compile", "steady")


def span_table(events):
    """Per-span-name rows ``(name, count, total_s, self_s)`` sorted by
    self time (descending).  Self time subtracts each direct child's
    duration from its parent (``id``/``parent`` linkage); a
    deterministic trace has no ``dur`` fields, so totals are 0 and the
    table degrades to counts."""
    spans = [ev for ev in events if ev.get("kind") == "span"]
    child = defaultdict(float)
    for ev in spans:
        if ev.get("parent"):
            child[ev["parent"]] += float(ev.get("dur", 0.0))
    per = {}
    for ev in spans:
        dur = float(ev.get("dur", 0.0))
        row = per.setdefault(ev["name"], [0, 0.0, 0.0])
        row[0] += 1
        row[1] += dur
        row[2] += dur - child.get(ev.get("id"), 0.0)
    rows = [(name, n, tot, slf) for name, (n, tot, slf) in per.items()]
    rows.sort(key=lambda r: (-r[3], r[0]))
    return rows


def compile_steady(events):
    """``fn -> phase -> (n_calls, total_s)`` for every profiled jit
    entry point: from the ProfiledFn wall spans (which carry a
    ``phase`` attr) when present, else from the ``profile/call_s``
    metric snapshot.  Empty when the trace was exported
    deterministically (wall records are dropped)."""
    out = defaultdict(lambda: defaultdict(lambda: [0, 0.0]))
    for ev in events:
        attrs = ev.get("attrs", {})
        if ev.get("kind") == "span" and attrs.get("phase") in _PHASES:
            cell = out[ev["name"]][attrs["phase"]]
            cell[0] += 1
            cell[1] += float(ev.get("dur", 0.0))
    if out:
        return out
    for ev in events:
        if ev.get("kind") == "metric" and ev.get("name") == "profile/call_s":
            tags = ev.get("tags", {})
            if tags.get("phase") in _PHASES:
                cell = out[tags.get("fn", "?")][tags["phase"]]
                cell[0] += int(ev.get("count", 0))
                cell[1] += float(ev.get("total", 0.0))
    return out


def _tagstr(tags: dict) -> str:
    return " ".join(f"{k}={v}" for k, v in sorted(tags.items())) or "-"


def _fmt_s(v: float) -> str:
    return f"{v:9.4f}s" if v == v else "      nan"


def render_text(events, path: str, top: int) -> None:
    kinds = defaultdict(int)
    for ev in events:
        kinds[ev.get("kind")] += 1
    deterministic = not any("ts" in ev for ev in events)
    mode = "deterministic (wall-clock fields stripped)" \
        if deterministic else "wall-clock"
    print(f"== {path}: {len(events)} events "
          f"({', '.join(f'{kinds[k]} {k}' for k in sorted(kinds))}; "
          f"{mode}) ==")

    rows = span_table(events)
    if rows:
        print("\n-- span time breakdown (self-sorted) --")
        print(f"{'span':<28}{'count':>7}{'total':>11}{'self':>11}")
        for name, n, tot, slf in rows[:top]:
            print(f"{name:<28}{n:>7}{_fmt_s(tot):>11}{_fmt_s(slf):>11}")
        if len(rows) > top:
            print(f"... {len(rows) - top} more (raise --top)")

    prof = compile_steady(events)
    if prof:
        print("\n-- compile vs steady (profiled jit entry points) --")
        for fn in sorted(prof):
            parts = []
            for phase in _PHASES:
                n, tot = prof[fn][phase]
                if n:
                    mean = tot / n
                    parts.append(f"{phase} {tot:.3f}s over {n} call(s) "
                                 f"({mean * 1e3:.1f} ms/call)")
            print(f"{fn:<28}" + "; ".join(parts))
    elif deterministic:
        print("\n-- compile vs steady: dropped by the deterministic "
              "export (re-run without --trace-deterministic) --")

    metrics = [ev for ev in events if ev.get("kind") == "metric"]
    cg = [ev for ev in metrics if ev.get("instrument") in ("counter",
                                                           "gauge")]
    if cg:
        print("\n-- counters / gauges --")
        for ev in cg:
            print(f"{ev['name']:<28}{ev.get('value', math.nan):>14.6g}  "
                  f"[{ev.get('instrument')}] {_tagstr(ev.get('tags', {}))}")
    hists = [ev for ev in metrics if ev.get("instrument") == "histogram"]
    if hists:
        print("\n-- histograms --")
        print(f"{'name':<28}{'count':>7}{'mean':>12}{'p50':>12}"
              f"{'p95':>12}{'p99':>12}  tags")
        for ev in hists[:top]:
            print(f"{ev['name']:<28}{ev.get('count', 0):>7}"
                  + "".join(f"{ev.get(f, math.nan):>12.4g}"
                            for f in ("mean", "p50", "p95", "p99"))
                  + f"  {_tagstr(ev.get('tags', {}))}")

    if any(ev.get("kind") == "event"
           and str(ev.get("name", "")).startswith("request/")
           for ev in events):
        s = summarize(fold_request_events(events))
        print("\n-- requests (folded from request/* events) --")
        print(f"offered {s['offered']}  done {s['done']}  "
              f"abandoned {s['abandoned']}  rejected {s['rejected']}  "
              f"preemptions {s['preemptions']}  tokens {s['tokens']}")
        for m in ("queue_wait", "first_token", "final"):
            pct = s[m]
            print(f"{m:<14}" + "  ".join(
                f"{q}={pct[q]:.4g}s" for q in ("p50", "p95", "p99")))


def report_rows(events):
    """The report as shared-schema ``(name, value, derived)`` rows
    (``--csv``; also what the CI smoke parses).  Metric tags are folded
    into the name as ``name[k=v ...]`` to keep one row per instrument."""
    rows = [("trace/events", len(events), "flight-recorder records")]
    kinds = defaultdict(int)
    for ev in events:
        kinds[ev.get("kind")] += 1
    rows += [(f"trace/kind/{k}", n, "") for k, n in sorted(kinds.items())]
    for name, n, tot, slf in span_table(events):
        rows.append((f"span/{name}", tot,
                     f"total s over {n} span(s), self {slf:.6g}s"))
    for fn, phases in sorted(compile_steady(events).items()):
        for phase in _PHASES:
            n, tot = phases[phase]
            if n:
                rows.append((f"profile/{fn}/{phase}_s", tot,
                             f"{n} call(s)"))
    for ev in events:
        if ev.get("kind") != "metric":
            continue
        tags = ev.get("tags", {})
        name = ev["name"] + (f"[{_tagstr(tags)}]" if tags else "")
        if ev.get("instrument") in ("counter", "gauge"):
            rows.append((name, ev.get("value", math.nan),
                         ev.get("instrument")))
        elif ev.get("instrument") == "histogram":
            rows.append((f"{name}/mean", ev.get("mean", math.nan),
                         f"histogram over {ev.get('count', 0)} obs"))
    if any(ev.get("kind") == "event"
           and str(ev.get("name", "")).startswith("request/")
           for ev in events):
        s = summarize(fold_request_events(events))
        rows += [(f"request/{k}", float(s[k]), "")
                 for k in ("offered", "done", "abandoned", "rejected",
                           "preemptions", "tokens")]
        for m in ("queue_wait", "first_token", "final"):
            for q, v in s[m].items():
                rows.append((f"request/{m}_{q}", v, "s"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="render a flight-recorder JSONL trace "
                    "(docs/observability.md)")
    ap.add_argument("trace",
                    help="JSONL written by a launcher's --trace-out")
    ap.add_argument("--chrome", default="",
                    help="also write Chrome trace_event JSON here "
                         "(open in chrome://tracing or ui.perfetto.dev)")
    ap.add_argument("--csv", action="store_true",
                    help="emit the report as name,value,derived rows "
                         "(the shared stats schema) instead of text "
                         "tables")
    ap.add_argument("--top", type=int, default=12,
                    help="max rows per text table")
    args = ap.parse_args(argv)

    events = read_jsonl(args.trace)
    problems = validate_events(events)
    if problems:
        for p in problems[:20]:
            print(f"[obsreport] schema: {p}", file=sys.stderr)
        print(f"[obsreport] FAIL: {len(problems)} schema problem(s) in "
              f"{args.trace}", file=sys.stderr)
        return 1

    if args.csv:
        print_csv_rows(report_rows(events), header=True)
    else:
        render_text(events, args.trace, args.top)
    if args.chrome:
        with open(args.chrome, "w", encoding="utf-8") as f:
            json.dump(chrome_trace(events), f)
        print(f"chrome trace -> {args.chrome}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
